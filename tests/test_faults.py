"""Fault-tolerant sweep execution: seeded fault injection, retry/backoff,
shard failover, watchdog abandonment, torn-journal kills, and the
concurrent-writer lockfile — with the central invariant differential-enforced:
any fault schedule that leaves >= 1 live device yields a bitwise-identical
``SweepResult`` to the fault-free run."""
import json
import os
import random
import subprocess
import sys
import tempfile

import pytest

from _hypothesis_compat import given, settings, st
from differential import assert_bitwise_equal_results
from repro.core import (
    CheckpointLockedError,
    FaultEvent,
    FaultPlan,
    FaultTelemetry,
    FaultTolerance,
    FaultToleranceExhausted,
    ShardEvaluationError,
    SweepCheckpoint,
    dlrm_rmc2_small,
    sweep,
    tpuv6e,
)
from repro.core.faults import (
    InjectedKill,
    InjectedWorkerCrash,
    TransientEvalError,
    backoff_seconds,
    classify_exception,
)
from repro.distributed.sweep_shard import (
    FaultInjector,
    evaluate_sharded,
    resolve_shard_plan,
)

GRID = dict(policies=("spm", "lru", "srrip", "pinning"),
            capacities=(1 << 16, 1 << 17, 1 << 18), ways=(4, 8),
            zipf_s=0.9, seed=0)
SHARDS = 4
# Watchdog bound for injected-hang tests. Generous vs the warm per-wave
# evaluation time (~0.1s here; the sharded_ref fixture pre-compiles the
# per-device executables) — a too-tight bound marks legitimately-busy
# shards hung, which is bitwise-safe but makes telemetry assertions racy.
HANG_TIMEOUT_S = 5.0


@pytest.fixture(scope="module")
def small_wl():
    return dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                           lookups=4, batch_size=8, num_batches=2)


@pytest.fixture(scope="module")
def ref(small_wl):
    """Fault-free unsharded reference — the bitwise ground truth."""
    return sweep(small_wl, tpuv6e(), **GRID)


@pytest.fixture(scope="module")
def sharded_ref(small_wl, ref):
    """Fault-free sharded run: warms the per-device executables (first
    sharded wave pays multi-second compiles; every fault test after this
    runs warm) and pins the production path's zero-fault telemetry."""
    sr = sweep(small_wl, tpuv6e(), devices=SHARDS, **GRID)
    assert_bitwise_equal_results(ref, sr, "fault-free sharded")
    return sr


# --------------------------------------------------------------------------
# Differential fault schedules (the acceptance invariant)
# --------------------------------------------------------------------------

def test_fault_free_sharded_telemetry_is_all_zero(sharded_ref):
    """No spurious retries/failovers in the production path."""
    assert not sharded_ref.telemetry.any_faults
    assert sharded_ref.telemetry.brief() == {
        f: 0 for f in FaultTelemetry.COUNTER_FIELDS}


def test_worker_crash_fails_over_bitwise(small_wl, ref, sharded_ref):
    plan = FaultPlan(events=(FaultEvent("crash", shard=1, round=0),))
    tele = FaultTelemetry()
    got = sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan,
                fault_telemetry=tele, **GRID)
    assert_bitwise_equal_results(ref, got, "crash failover")
    assert got.telemetry is tele
    assert tele.worker_crashes == 1
    assert tele.failed_shards == 1
    assert tele.failovers == 1
    assert tele.retries == 0
    assert tele.failover_keys > 0
    assert 1 in tele.shards and "crash" in tele.shards[1]["failures"]


def test_transient_double_retry_bitwise(small_wl, ref, sharded_ref):
    plan = FaultPlan(events=(
        FaultEvent("transient", shard=0, round=0, count=2),))
    tol = FaultTolerance(max_retries=2, backoff_base_s=0.01)
    tele = FaultTelemetry()
    got = sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan,
                fault_tolerance=tol, fault_telemetry=tele, **GRID)
    assert_bitwise_equal_results(ref, got, "transient x2 retry")
    assert tele.retries == 2
    assert tele.transient_errors == 2
    assert tele.failovers == 0            # recovered in place
    assert tele.failed_shards == 0
    assert tele.shards[0]["retries"] == 2


def test_retry_exhaustion_falls_back_to_failover(small_wl, ref, sharded_ref):
    """More transients than the retry budget: the shard fails over instead
    of looping forever — and the result is still bitwise."""
    plan = FaultPlan(events=(
        FaultEvent("transient", shard=2, round=0, count=3),))
    tol = FaultTolerance(max_retries=1, backoff_base_s=0.01)
    tele = FaultTelemetry()
    got = sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan,
                fault_tolerance=tol, fault_telemetry=tele, **GRID)
    assert_bitwise_equal_results(ref, got, "retry exhaustion failover")
    assert tele.retries == 1
    assert tele.retries_exhausted == 1
    assert tele.failovers == 1


def test_hung_shard_watchdog_failover_bitwise(small_wl, ref, sharded_ref):
    plan = FaultPlan(events=(FaultEvent("hang", shard=2, round=0),))
    tol = FaultTolerance(shard_timeout_s=HANG_TIMEOUT_S, backoff_base_s=0.01)
    tele = FaultTelemetry()
    got = sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan,
                fault_tolerance=tol, fault_telemetry=tele, **GRID)
    assert_bitwise_equal_results(ref, got, "hung-shard failover")
    assert tele.hung_shards == 1
    assert tele.failovers == 1
    assert "hang" in tele.shards[2]["failures"]


def test_kill_and_resume_mid_failover_bitwise(small_wl, ref, sharded_ref,
                                              tmp_path):
    """Round 0 crashes a shard (failover), round 1 dies mid journal append
    (torn tail). The rerun resumes every intact key, re-evaluates the torn
    one, and lands bitwise on the reference."""
    path = str(tmp_path / "faulty.ckpt")
    plan = FaultPlan(events=(FaultEvent("crash", shard=1, round=0),
                             FaultEvent("torn_write", round=1)))
    tele = FaultTelemetry()
    ck = SweepCheckpoint(path, cadence=8)
    with pytest.raises(InjectedKill):
        sweep(small_wl, tpuv6e(), devices=SHARDS, checkpoint=ck,
              fault_plan=plan, fault_telemetry=tele, **GRID)
    ck.close()
    assert tele.worker_crashes == 1
    assert tele.failovers == 1
    assert tele.torn_writes == 1
    resumed = sweep(small_wl, tpuv6e(), devices=SHARDS, checkpoint=path,
                    **GRID)
    assert_bitwise_equal_results(ref, resumed, "kill-and-resume mid-failover")
    # The torn frame (and only it) was re-evaluated.
    assert 0 < resumed.resumed_keys < resumed.distinct_memo_keys
    assert resumed.resumed_keys == resumed.distinct_memo_keys - 1
    assert not os.path.exists(path + ".lock")


def test_combined_chaos_schedule_bitwise(small_wl, ref, sharded_ref,
                                         tmp_path):
    """Crash + transient + hang in one checkpointed multi-round sweep."""
    path = str(tmp_path / "chaos.ckpt")
    plan = FaultPlan(events=(
        FaultEvent("transient", shard=0, round=0, count=2),
        FaultEvent("crash", shard=1, round=0),
        FaultEvent("hang", shard=2, round=1),
    ))
    tol = FaultTolerance(max_retries=2, backoff_base_s=0.01,
                         shard_timeout_s=HANG_TIMEOUT_S)
    tele = FaultTelemetry()
    ck = SweepCheckpoint(path, cadence=8)   # 14 memo keys -> 2 rounds
    got = sweep(small_wl, tpuv6e(), devices=SHARDS, checkpoint=ck,
                fault_plan=plan, fault_tolerance=tol, fault_telemetry=tele,
                **GRID)
    ck.close()
    assert_bitwise_equal_results(ref, got, "combined chaos")
    assert tele.retries == 2
    assert tele.worker_crashes == 1
    assert tele.hung_shards == 1
    assert tele.failovers == 2


# --------------------------------------------------------------------------
# Strict mode + fatal errors (satellite: shard-context exceptions,
# sibling-result preservation)
# --------------------------------------------------------------------------

def test_strict_raises_with_shard_context(small_wl, sharded_ref):
    plan = FaultPlan(events=(FaultEvent("crash", shard=0, round=0),))
    with pytest.raises(ShardEvaluationError, match="strict") as ei:
        sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan,
              fault_tolerance=FaultTolerance(strict=True), **GRID)
    exc = ei.value
    assert exc.shard == 0
    assert exc.device                    # device string attached
    assert exc.keys and exc.class_groups
    assert isinstance(exc.cause, InjectedWorkerCrash)
    # Sibling shards finished before the supervisor raised: their results
    # ride on the exception instead of being discarded.
    assert len(exc.completed) > 0


def test_fatal_error_preserves_siblings_via_checkpoint(small_wl, ref,
                                                       sharded_ref, tmp_path):
    """A fatal (bug-class) error never fails over — but the journal keeps
    every completed sibling key, so the rerun only redoes the broken shard."""
    path = str(tmp_path / "fatal.ckpt")
    plan = FaultPlan(events=(FaultEvent("fatal", shard=3, round=0),))
    with pytest.raises(ShardEvaluationError) as ei:
        sweep(small_wl, tpuv6e(), devices=SHARDS, checkpoint=path,
              fault_plan=plan, **GRID)
    assert len(ei.value.completed) > 0
    resumed = sweep(small_wl, tpuv6e(), devices=SHARDS, checkpoint=path,
                    **GRID)
    assert_bitwise_equal_results(ref, resumed, "fatal + sibling resume")
    assert resumed.resumed_keys == len(ei.value.completed)


def test_all_shards_dead_exhausts_tolerance():
    """Unit-level: crash every shard -> FaultToleranceExhausted (no device
    left to fail over onto). Uses a stub eval_fn, no engine work."""
    items = {(i,): (None, ("g", i)) for i in range(6)}
    plan = resolve_shard_plan(3)
    inj = FaultInjector(FaultPlan(events=tuple(
        FaultEvent("crash", shard=s, round=0) for s in range(3))))
    inj.begin_round()
    with pytest.raises(FaultToleranceExhausted):
        evaluate_sharded(items, plan, lambda part: {k: [0] for k in part},
                         injector=inj)


def test_failover_depth_cap():
    """A fault that follows the keys cannot livelock: crash shard 0 in
    every wave and cap failover depth at 1."""
    items = {(i,): (None, ("g", i)) for i in range(6)}
    plan = resolve_shard_plan(3)
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent("crash", shard=0, round=0),
        FaultEvent("crash", shard=1, round=0),
        FaultEvent("crash", shard=2, round=0),
    )))
    inj.begin_round()
    tol = FaultTolerance(max_failover_rounds=1)
    with pytest.raises(FaultToleranceExhausted):
        evaluate_sharded(items, plan, lambda part: {k: [0] for k in part},
                         tolerance=tol, injector=inj)


# --------------------------------------------------------------------------
# Plan validation + unit behavior
# --------------------------------------------------------------------------

def test_shard_events_require_devices(small_wl):
    plan = FaultPlan(events=(FaultEvent("crash", shard=0, round=0),))
    with pytest.raises(ValueError, match="not sharded"):
        sweep(small_wl, tpuv6e(), fault_plan=plan, **GRID)


def test_hang_requires_watchdog(small_wl):
    plan = FaultPlan(events=(FaultEvent("hang", shard=0, round=0),))
    with pytest.raises(ValueError, match="watchdog"):
        sweep(small_wl, tpuv6e(), devices=SHARDS, fault_plan=plan, **GRID)


def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor")
    with pytest.raises(ValueError, match="invalid fault event"):
        FaultEvent("crash", count=0)


def test_injector_counts_and_audit_log():
    plan = FaultPlan(events=(
        FaultEvent("transient", shard=1, round=0, count=2),))
    inj = FaultInjector(plan)
    inj.begin_round()
    inj.fire(0)                                  # wrong shard: no-op
    with pytest.raises(TransientEvalError):
        inj.fire(1)
    with pytest.raises(TransientEvalError):
        inj.fire(1)
    inj.fire(1)                                  # count exhausted: no-op
    assert inj.fired == [("transient", 1, 0), ("transient", 1, 0)]
    assert not inj.maybe_tear()                  # no torn_write scheduled


def test_classify_exception_taxonomy():
    assert classify_exception(TransientEvalError("x")) == "transient"
    assert classify_exception(OSError("disk")) == "transient"
    assert classify_exception(RuntimeError("UNAVAILABLE: backend")) \
        == "transient"
    assert classify_exception(RuntimeError("RESOURCE_EXHAUSTED")) \
        == "transient"
    assert classify_exception(RuntimeError("device lost")) == "crash"
    assert classify_exception(InjectedWorkerCrash("x")) == "crash"
    assert classify_exception(KeyboardInterrupt()) == "kill"
    assert classify_exception(InjectedKill("x")) == "kill"
    assert classify_exception(ValueError("bug")) == "fatal"


def test_backoff_is_seeded_exponential_with_bounded_jitter():
    tol = FaultTolerance(backoff_base_s=0.05, backoff_factor=2.0,
                         jitter_frac=0.25, seed=7)
    for shard in (0, 3):
        for attempt in (1, 2, 3):
            lo = 0.05 * 2.0 ** (attempt - 1)
            v = backoff_seconds(tol, shard, attempt)
            assert lo <= v <= lo * 1.25
            assert v == backoff_seconds(tol, shard, attempt)  # deterministic
    # Jitter decorrelates shards (same attempt, different delay).
    assert backoff_seconds(tol, 0, 1) != backoff_seconds(tol, 1, 1)


def test_chaos_plan_is_deterministic_and_leaves_a_survivor():
    for seed in range(25):
        p1 = FaultPlan.chaos(seed, num_shards=4, num_rounds=3, events=6)
        p2 = FaultPlan.chaos(seed, num_shards=4, num_rounds=3, events=6)
        assert p1 == p2
        lethal = {}
        for e in p1.events:
            if e.kind in ("crash", "hang"):
                lethal[e.round] = lethal.get(e.round, 0) + 1
        assert all(n <= 3 for n in lethal.values())


def test_telemetry_in_to_json(sharded_ref):
    payload = json.loads(sharded_ref.to_json())
    ft = payload["fault_telemetry"]
    assert ft["retries"] == 0 and ft["failovers"] == 0
    assert "shards" in ft and len(ft["shards"]) >= 1


# --------------------------------------------------------------------------
# Checkpoint lockfile (satellite: concurrent-writer guard)
# --------------------------------------------------------------------------

def test_lock_blocks_live_foreign_writer_and_takes_over_dead(
        small_wl, ref, tmp_path):
    path = str(tmp_path / "locked.ckpt")
    proc = subprocess.Popen(
        [sys.executable, "-c", "import time; time.sleep(120)"])
    try:
        with open(path + ".lock", "w") as f:
            json.dump({"pid": proc.pid, "path": path, "time": 0}, f)
        with pytest.raises(CheckpointLockedError, match="live"):
            sweep(small_wl, tpuv6e(), checkpoint=path, **GRID)
    finally:
        proc.kill()
        proc.wait()
    # Holder is dead now: stale takeover, and the sweep completes + unlocks.
    got = sweep(small_wl, tpuv6e(), checkpoint=path, **GRID)
    assert_bitwise_equal_results(ref, got, "stale-lock takeover")
    assert not os.path.exists(path + ".lock")


def test_lock_same_process_reopen_and_unreadable_lock(small_wl, ref,
                                                      tmp_path):
    path = str(tmp_path / "reopen.ckpt")
    # Unreadable/garbage lock payloads are treated as stale (taken over).
    with open(path + ".lock", "w") as f:
        f.write("not json at all")
    ck = SweepCheckpoint(path)
    first = sweep(small_wl, tpuv6e(), checkpoint=ck, **GRID)
    assert_bitwise_equal_results(ref, first, "garbage-lock takeover")
    # sweep() leaves caller-owned instances open (lock held); the same
    # process re-opening — the kill-and-resume pattern — must not deadlock
    # on its own lock.
    again = sweep(small_wl, tpuv6e(), checkpoint=ck, **GRID)
    assert_bitwise_equal_results(ref, again, "same-process reopen")
    ck.close()
    assert not os.path.exists(path + ".lock")


def test_open_failure_releases_lock(small_wl, tmp_path):
    path = str(tmp_path / "mismatch.ckpt")
    first = SweepCheckpoint(path)
    first.open({"spec": "a"})
    first.close()
    bad = SweepCheckpoint(path)
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        bad.open({"spec": "b"})
    # The failed open must not leave its lock behind.
    assert not os.path.exists(path + ".lock")
    ok = SweepCheckpoint(path)
    ok.open({"spec": "a"})
    ok.close()


# --------------------------------------------------------------------------
# Journal corruption fuzz (satellite: truncate-at-first-invalid, never a
# silently wrong resume)
# --------------------------------------------------------------------------

_FUZZ_CACHE = {}


def _fuzz_base():
    """Build (once) a completed journal's bytes + the reference result."""
    if not _FUZZ_CACHE:
        wl = dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                             lookups=4, batch_size=8, num_batches=2)
        d = tempfile.mkdtemp(prefix="faultfuzz")
        path = os.path.join(d, "base.ckpt")
        ref = sweep(wl, tpuv6e(), checkpoint=path, **GRID)
        with open(path, "rb") as f:
            _FUZZ_CACHE.update(wl=wl, ref=ref, raw=f.read())
    return _FUZZ_CACHE


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 1_000_000))
def test_random_corruption_always_heals_never_lies(seed):
    """Flip one byte or truncate anywhere in a completed journal: the
    resume must (a) produce the bitwise-identical result — re-evaluating
    dropped keys, never half-restoring them — and (b) leave the journal
    fully valid again (a second resume restores every key)."""
    base = _fuzz_base()
    rng = random.Random(seed)
    data = bytearray(base["raw"])
    if rng.random() < 0.5:
        idx = rng.randrange(len(data))
        data[idx] ^= rng.randrange(1, 256)
    else:
        data = data[: rng.randrange(1, len(data))]
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "corrupt.ckpt")
        with open(path, "wb") as f:
            f.write(bytes(data))
        healed = sweep(base["wl"], tpuv6e(), checkpoint=path, **GRID)
        assert_bitwise_equal_results(base["ref"], healed,
                                     f"corruption seed={seed}")
        again = sweep(base["wl"], tpuv6e(), checkpoint=path, **GRID)
        assert_bitwise_equal_results(base["ref"], again,
                                     f"healed journal seed={seed}")
        assert again.resumed_keys == again.distinct_memo_keys
