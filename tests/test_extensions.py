"""Extended coverage: interleave knob, sqrt-domain nu quantization, DLRM
training, serving engine on MoE, elastic mesh edge cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tpuv6e
from repro.core.memory.dram import DramModel, simulate_dram
from repro.core.trace import generate_zipf_trace
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)


def _vec_lines(n_vec=5000, rows=500_000, seed=1):
    v = generate_zipf_trace(n_vec, rows, 1.0, seed=seed)
    return (v[:, None] * 8 + np.arange(8)[None, :]).reshape(-1)


def test_coarse_interleave_beats_fine_for_vector_gathers():
    """512 B vectors: one-row placement (>=512 B interleave) means 1 activate
    per vector instead of 8 — must be materially faster."""
    lines = _vec_lines()

    def run(interleave):
        hw = tpuv6e()
        hw = hw.replace(offchip=dataclasses.replace(hw.offchip,
                                                    interleave_bytes=interleave))
        return simulate_dram(lines, DramModel.from_hardware(hw))

    fine, coarse = run(64), run(512)
    assert coarse.row_hit_rate > fine.row_hit_rate
    assert coarse.finish_cycle < fine.finish_cycle * 0.7


def test_nu_quantization_never_dequantizes_to_zero():
    """sqrt-domain second moment with half-step floor: no m/(sqrt(0)+eps)
    blowups (the failure mode of plain absmax int8 — see optimizer.py)."""
    v = jnp.concatenate([jnp.full((255,), 1e-4), jnp.array([10.0])])  # one hot block
    packed = opt._write_moment(v, True, "nu")
    back = opt._read_moment(packed, v, True, "nu")
    assert float(back.min()) > 0.0
    # the large entry survives accurately
    assert abs(float(back[-1]) - 10.0) / 10.0 < 0.02


def test_mu_quantization_signed_roundtrip(rng):
    m = jnp.asarray(rng.standard_normal(512) * 1e-3, jnp.float32)
    packed = opt._write_moment(m, True, "mu")
    back = opt._read_moment(packed, m, True, "mu")
    assert float(jnp.max(jnp.abs(back - m))) <= float(jnp.max(jnp.abs(m))) / 127 + 1e-9


def test_dlrm_training_converges(rng):
    from repro.data.dlrm_data import DLRMDataConfig, dlrm_batch
    from repro.models import dlrm

    cfg = dlrm.smoke_config()
    params = dlrm.init(KEY, cfg)
    dcfg = DLRMDataConfig(num_tables=cfg.num_tables, rows_per_table=cfg.rows_per_table,
                          lookups_per_table=cfg.lookups_per_table, batch_size=64)

    @jax.jit
    def step(params, dense, sparse, labels):
        def loss_fn(p):
            out = dlrm.forward(p, dense, sparse, cfg)
            return dlrm.bce_loss(out, labels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, grads)
        return params, loss

    losses = []
    for i in range(60):
        b = dlrm_batch(dcfg, i)
        params, loss = step(params, jnp.asarray(b["dense"]),
                            jnp.asarray(b["sparse"]), jnp.asarray(b["labels"]))
        losses.append(float(loss))
    # BCE starts near ln2; the dense-feature signal is quickly learnable
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, (
        losses[:3], losses[-3:]
    )
    assert np.isfinite(losses).all()


def test_serving_engine_moe():
    from repro.models import family_module, get_smoke_config
    from repro.serving import ServeConfig, ServingEngine

    cfg = get_smoke_config("deepseek_v2_lite_16b")
    mod = family_module(cfg)
    params = mod.init_lm(KEY, cfg)
    engine = ServingEngine(cfg, params, ServeConfig(batch=2, max_seq=48))
    prompts = np.random.default_rng(1).integers(0, cfg.vocab, (2, 8), dtype=np.int32)
    out = engine.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert out.min() >= 0 and out.max() < cfg.vocab


def test_elastic_plan_multipod_shrink():
    from repro.runtime import plan_elastic

    # lose 32 chips from a 512-chip 2-pod mesh: keep model=16
    plan = plan_elastic((2, 16, 16), ("pod", "data", "model"), 480)
    assert plan.mesh_shape[-1] == 16
    assert plan.mesh_shape[0] * plan.mesh_shape[1] * 16 <= 480
