"""Multi-core CoreCluster MemorySystem: degenerate bit-exactness, per-core
trace-sharding conservation laws (property-tested), shared-DRAM contention,
per-table policy mixes, sweep axes, and config validation."""
import numpy as np
import pytest

from _hypothesis_compat import given, st
from differential import assert_bitwise_equal_results, golden_pair
from repro.core import (
    LookupSharding,
    MemorySystem,
    MultiCoreMemorySystem,
    OnChipPolicy,
    Topology,
    available_policies,
    dlrm_rmc2_small,
    memory_system_for,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core.engine import build_embedding_traces
from repro.core.memory.dram import (
    DramModel,
    dram_timing_segmented,
    simulate_dram_contended,
)
from repro.core.memory.system import EmbeddingTrace
from repro.core.trace import (
    expand_trace,
    generate_zipf_trace,
    shard_lookup_cores,
    shard_trace,
    table_core_of,
)
from repro.core.workload import EmbeddingOpSpec


def _etrace(spec, batch_sizes, seed=0):
    traces = []
    for bi, bsz in enumerate(batch_sizes):
        it = generate_zipf_trace(
            bsz * spec.num_tables * spec.lookups_per_sample,
            spec.rows_per_table, 1.0, seed=seed + bi)
        traces.append(expand_trace(it, spec, bsz, seed=seed + bi))
    return EmbeddingTrace(spec, traces)


_SPEC = EmbeddingOpSpec(num_tables=3, rows_per_table=3000, dim=128,
                        lookups_per_sample=6, dtype_bytes=4)


# --------------------------------------------------------------------------
# Acceptance: num_cores=1 / private is bit-exact vs the single-core path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_degenerate_cluster_bitexact_per_policy(policy):
    hw = tpuv6e().with_policy(OnChipPolicy(policy), capacity_bytes=1 << 18)
    assert hw.num_cores == 1 and hw.topology == Topology.PRIVATE
    golden_pair(
        lambda et: MultiCoreMemorySystem.from_hardware(hw).simulate_embedding(et),
        lambda et: MemorySystem.from_hardware(hw).simulate_embedding(et),
        corpus=[_etrace(_SPEC, [8, 8])],
        label=policy,
    )()
    # and the factory picks the plain single-core pipeline
    assert isinstance(memory_system_for(hw), MemorySystem)


@pytest.mark.parametrize("policy", sorted(available_policies()))
def test_degenerate_cluster_bitexact_full_simulate(policy):
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    hw = tpuv6e().with_policy(OnChipPolicy(policy), capacity_bytes=1 << 17)
    ref = simulate(wl, hw, seed=0, zipf_s=1.0)
    got = simulate(wl, hw.with_cluster(1, "private"), seed=0, zipf_s=1.0)
    assert not got.diff(ref)


# --------------------------------------------------------------------------
# Sharding conservation laws (property tests, hypothesis-compatible)
# --------------------------------------------------------------------------

@given(num_cores=st.integers(1, 8), mode=st.sampled_from(["batch", "table_hash"]))
def test_sharding_conserves_lookups_per_batch(num_cores, mode):
    """Shard lookup counts sum to the parent's per-batch counts — for every
    core count, sharding mode, and heterogeneous batch boundaries."""
    et = _etrace(_SPEC, [5, 11, 2], seed=3)
    concat = et.concat
    shards = shard_trace(concat, num_cores, mode)
    assert len(shards) == num_cores
    per_batch = np.zeros(concat.num_batches, dtype=np.int64)
    n_total = 0
    for sh in shards:
        assert sh.concat.num_batches == concat.num_batches
        per_batch += sh.concat.lookups_per_batch
        n_total += len(sh)
        # shard boundaries are consistent with its own content
        assert len(sh.concat) == sh.concat.boundaries[-1]
        # global positions round-trip to the parent's lookups
        assert np.array_equal(concat.table_ids[sh.lookup_index], sh.concat.table_ids)
        assert np.array_equal(concat.row_ids[sh.lookup_index], sh.concat.row_ids)
    assert n_total == len(concat)
    assert np.array_equal(per_batch, concat.lookups_per_batch)


@given(num_cores=st.integers(1, 8))
def test_table_hash_sharding_is_table_exclusive(num_cores):
    """table_hash mode places each table on exactly one core."""
    et = _etrace(_SPEC, [7, 4], seed=5)
    core = shard_lookup_cores(et.concat, num_cores, "table_hash")
    expect = table_core_of(et.concat.table_ids, num_cores)
    assert np.array_equal(core, expect)
    for t in range(_SPEC.num_tables):
        owners = np.unique(core[et.concat.table_ids == t])
        assert owners.size <= 1


@given(num_cores=st.integers(2, 6),
       mode=st.sampled_from(["batch", "table_hash"]),
       policy=st.sampled_from(["spm", "lru", "pinning"]))
def test_multicore_conserves_accesses(num_cores, mode, policy):
    """Total line accesses (hits + misses) are invariant under the core
    count, topology, and sharding mode — sharding only partitions work."""
    hw1 = tpuv6e().with_policy(OnChipPolicy(policy), capacity_bytes=1 << 17)
    et = _etrace(_SPEC, [6, 9], seed=1)
    ref = MemorySystem.from_hardware(hw1).simulate_embedding(et)
    ref_acc = [s.cache_hits + s.cache_misses for s in ref]
    for topo in ("private", "shared"):
        hw = hw1.with_cluster(num_cores, topo, mode)
        got = memory_system_for(hw).simulate_embedding(et)
        assert [s.cache_hits + s.cache_misses for s in got] == ref_acc, (topo,)
        assert [s.onchip_reads for s in got] == [s.onchip_reads for s in ref]


def test_heterogeneous_batches_survive_sharding_in_stats():
    """Per-core per-batch attribution follows the true (heterogeneous)
    boundaries: aggregated SPM counts per batch stay analytic."""
    batch_sizes = [5, 11, 2]
    et = _etrace(_SPEC, batch_sizes)
    lpv = _SPEC.vector_bytes // 64
    hw = tpuv6e().with_cluster(3, "private", "batch")   # SPM default
    stats = memory_system_for(hw).simulate_embedding(et)
    for s, bsz in zip(stats, batch_sizes):
        n_lines = bsz * _SPEC.num_tables * _SPEC.lookups_per_sample * lpv
        assert s.onchip_reads == n_lines
        assert s.offchip_reads == n_lines
        assert s.cache_misses == n_lines and s.cache_hits == 0
        assert sum(pc.lookups for pc in s.per_core) == (
            bsz * _SPEC.num_tables * _SPEC.lookups_per_sample
        )


# --------------------------------------------------------------------------
# Shared-DRAM contention
# --------------------------------------------------------------------------

def test_contended_dram_single_source_matches_segmented(rng):
    dm = DramModel.from_hardware(tpuv6e())
    lines = rng.integers(0, 200_000, size=6000)
    seg = np.sort(rng.integers(0, 3, size=6000))
    ref = dram_timing_segmented(lines, seg, 3, dm)
    got, fin = simulate_dram_contended(
        lines, seg, np.zeros(6000, dtype=np.int64), 3, 1, dm)
    for s in range(3):
        assert got[s].finish_cycle == ref[s].finish_cycle
        assert got[s].row_hits == ref[s].row_hits
        assert got[s].accesses == ref[s].accesses
        assert fin[s, 0] == ref[s].finish_cycle


def test_contention_delays_vs_private_dram(rng):
    """A source sharing DRAM with another finishes no earlier than it would
    alone, and the shared finish bounds every per-source finish."""
    dm = DramModel.from_hardware(tpuv6e())
    n = 8000
    lines = rng.integers(0, 400_000, size=n)
    seg = np.zeros(n, dtype=np.int64)
    src = rng.integers(0, 2, size=n)
    got, fin = simulate_dram_contended(lines, seg, src, 1, 2, dm)
    for c in range(2):
        alone = dram_timing_segmented(
            lines[src == c], np.zeros(int((src == c).sum()), dtype=np.int64), 1, dm
        )[0]
        assert fin[0, c] >= alone.finish_cycle
        assert fin[0, c] <= got[0].finish_cycle
    assert got[0].finish_cycle == pytest.approx(fin[0].max())


def test_multicore_dram_slower_than_fresh_per_core_sum():
    """The cluster's per-batch DRAM time reflects contention: it is at least
    the slowest core's stand-alone burst (fresh-state-per-core would be)."""
    hw = tpuv6e().with_policy(OnChipPolicy.SPM).with_cluster(4, "private", "batch")
    et = _etrace(_SPEC, [16])
    stats = memory_system_for(hw).simulate_embedding(et)
    s = stats[0]
    slowest_core = max(pc.dram_finish_cycles for pc in s.per_core)
    assert s.dram_cycles == pytest.approx(slowest_core)
    # single-core run over the full stream == shared time for all-miss SPM
    ref = MemorySystem.from_hardware(
        tpuv6e().with_policy(OnChipPolicy.SPM)
    ).simulate_embedding(et)
    assert s.dram_cycles == ref[0].dram_cycles


# --------------------------------------------------------------------------
# Per-table policy mixes
# --------------------------------------------------------------------------

def test_degenerate_policy_mix_bitexact():
    """A mix assigning every table the default policy classifies bit-exactly
    like the unmixed path (fraction-1 partition is the identity)."""
    for policy in ("lru", "spm", "pinning"):
        hw = tpuv6e().with_policy(OnChipPolicy(policy), capacity_bytes=1 << 18)
        hwm = hw.with_policy_mix({t: policy for t in range(_SPEC.num_tables)})
        golden_pair(
            lambda et: MemorySystem.from_hardware(hwm).simulate_embedding(et),
            lambda et: MemorySystem.from_hardware(hw).simulate_embedding(et),
            corpus=[_etrace(_SPEC, [8, 8])],
            label=policy,
        )()


def test_policy_mix_pinned_hot_cached_cold():
    """Hot table pinned + cold tables cached: runs under both topologies,
    conserves accesses, and the pinned table actually hits on-chip."""
    hw = (
        tpuv6e()
        .with_policy(OnChipPolicy.LRU, capacity_bytes=1 << 18)
        .with_policy_mix({0: "pinning"})
    )
    et = _etrace(_SPEC, [8, 8])
    mixed = MemorySystem.from_hardware(hw).simulate_embedding(et)
    plain = MemorySystem.from_hardware(
        hw.with_policy_mix(None)
    ).simulate_embedding(et)
    tot = lambda stats: sum(s.cache_hits + s.cache_misses for s in stats)
    assert tot(mixed) == tot(plain)
    assert sum(s.cache_hits for s in mixed) > 0
    # pinned preload shows up as batch-0 setup writes
    assert mixed[0].onchip_writes > mixed[0].cache_misses
    # multi-core: the mix rides along inside each core's pipeline
    multi = memory_system_for(hw.with_cluster(2, "private")).simulate_embedding(et)
    assert tot(multi) == tot(plain)


def test_policy_mix_validation():
    from repro.core.memory.policies import resolve_policy_mix

    hw = tpuv6e()
    with pytest.raises(ValueError, match="duplicate"):
        # dict keys cannot collide, so exercise the normalized-tuple check
        resolve_policy_mix(((0, "lru"), (0, "spm")), "spm", 2)
    with pytest.raises(ValueError, match="out of range"):
        simulate(
            dlrm_rmc2_small(num_tables=2, rows_per_table=500, lookups=2,
                            batch_size=4),
            hw.with_policy_mix({7: "lru"}),
        )


# --------------------------------------------------------------------------
# Sweepable cluster axes
# --------------------------------------------------------------------------

def test_sweep_cluster_axes_bitexact():
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    sr = sweep(wl, tpuv6e(), policies=("spm", "lru"), capacities=(1 << 16,),
               ways=(4,), zipf_s=0.9, seed=0,
               num_cores=(1, 2), topologies=("private", "shared"))
    assert sr.num_configs == 2 * 1 * 1 * 2 * 2
    assert {(e.config.num_cores, e.config.topology) for e in sr.entries} == {
        (1, "private"), (1, "shared"), (2, "private"), (2, "shared")}
    for e in sr.entries:
        c = e.config
        hw = tpuv6e().with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        ).with_cluster(c.num_cores, c.topology)
        ref = simulate(wl, hw, seed=0, zipf_s=c.zipf_s)
        assert_bitwise_equal_results(e.result, ref, label=c.label)


def test_sweep_batched_scans_bitexact_vs_unbatched():
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    kw = dict(policies=("lru", "srrip"), capacities=(1 << 16, 1 << 17, 1 << 18),
              ways=(4, 8), zipf_s=0.9, seed=0)
    a = sweep(wl, tpuv6e(), batch_scans=True, **kw)
    b = sweep(wl, tpuv6e(), batch_scans=False, **kw)
    assert a.num_configs == b.num_configs == 12
    assert_bitwise_equal_results(a, b)


# --------------------------------------------------------------------------
# Config validation (with_onchip / with_policy / with_cluster)
# --------------------------------------------------------------------------

def test_with_onchip_rejects_unknown_kwargs():
    with pytest.raises(ValueError, match="unknown OnChipMemory parameter"):
        tpuv6e().with_onchip(capacty_bytes=1 << 20)   # typo'd key
    with pytest.raises(ValueError, match="HardwareConfig fields"):
        tpuv6e().with_onchip(num_cores=4)             # misplaced cluster knob
    with pytest.raises(ValueError, match="unknown OnChipMemory parameter"):
        tpuv6e().with_policy(OnChipPolicy.LRU, way=8)


def test_with_cluster_validation():
    hw = tpuv6e().with_cluster(4, "shared", "table_hash")
    assert hw.num_cores == 4
    assert hw.topology == Topology.SHARED
    assert hw.lookup_sharding == LookupSharding.TABLE_HASH
    with pytest.raises(ValueError):
        tpuv6e().with_cluster(0)
    with pytest.raises(ValueError):
        tpuv6e().with_cluster(2, "ring")
