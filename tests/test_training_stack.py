"""Training substrate: loss convergence, chunked CE == full CE, microbatch
equivalence, quantized-optimizer parity, gradient compression convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import LMDataConfig, lm_batch
from repro.models import get_smoke_config
from repro.training import (
    AdamWConfig,
    CompressionConfig,
    TrainConfig,
    build_train_step,
    chunked_softmax_xent,
    full_softmax_xent,
    init_state,
)
from repro.training import optimizer as opt
from repro.training.compression import compress_grads, init_error

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_equals_full(rng):
    B, S, D, V = 2, 64, 32, 97
    hidden = jnp.asarray(rng.standard_normal((B, S, D)), jnp.float32)
    head = jnp.asarray(rng.standard_normal((D, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    full = full_softmax_xent(hidden @ head, labels)
    for chunk in (8, 16, 64):
        c = chunked_softmax_xent(hidden, head, labels, chunk=chunk)
        np.testing.assert_allclose(float(c), float(full), rtol=1e-5)


def _run(cfg, tcfg, steps=25, seed=0):
    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
    step = jax.jit(build_train_step(cfg, tcfg))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases():
    cfg = get_smoke_config("stablelm_3b")
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
                       loss_chunk=16)
    losses = _run(cfg, tcfg, steps=25)
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_equivalence():
    """grad-accum over 4 microbatches == single batch (same data)."""
    cfg = get_smoke_config("stablelm_3b")
    l1 = _run(cfg, TrainConfig(adamw=AdamWConfig(lr=1e-3), loss_chunk=16,
                               microbatches=1), steps=5)
    l4 = _run(cfg, TrainConfig(adamw=AdamWConfig(lr=1e-3), loss_chunk=16,
                               microbatches=4), steps=5)
    np.testing.assert_allclose(l1, l4, rtol=2e-2, atol=2e-2)


def test_quantized_optimizer_tracks_fp32():
    cfg = get_smoke_config("stablelm_3b")
    base = _run(cfg, TrainConfig(adamw=AdamWConfig(lr=3e-3), loss_chunk=16), steps=15)
    quant = _run(cfg, TrainConfig(adamw=AdamWConfig(lr=3e-3, quantize_state=True),
                                  loss_chunk=16), steps=15)
    assert quant[-1] < base[0] - 0.25               # it converges
    assert abs(quant[-1] - base[-1]) < 0.3          # and tracks fp32 closely


def test_quantize_roundtrip_accuracy(rng):
    for shape in [(64,), (8, 130), (3, 5, 256)]:
        x = jnp.asarray(rng.standard_normal(shape) * 3, jnp.float32)
        q, s = opt._quantize(x)
        assert q.shape == x.shape and q.dtype == jnp.int8
        back = opt._dequantize(q, s, x.shape, x.size)
        err = float(jnp.max(jnp.abs(back - x)))
        assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6


def test_lr_schedule_shape():
    c = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(opt.lr_schedule(c, jnp.int32(0))) == 0.0
    assert abs(float(opt.lr_schedule(c, jnp.int32(10))) - 1.0) < 1e-6
    assert float(opt.lr_schedule(c, jnp.int32(100))) <= 0.1 + 1e-6


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def test_int8_compression_with_error_feedback_converges():
    cfg = get_smoke_config("stablelm_3b")
    tc = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
                     compression=CompressionConfig(kind="int8"), loss_chunk=16)
    losses = _run(cfg, tc, steps=25)
    assert losses[-1] < losses[0] - 0.5, losses


def test_topk_error_feedback_unbiased_on_quadratic():
    """EF-topk SGD converges on a quadratic where plain topk stalls dims."""
    w = jnp.asarray(np.linspace(1, 3, 32), jnp.float32)
    target = jnp.zeros(32)
    ccfg = CompressionConfig(kind="topk", topk_density=0.125)
    err = init_error({"w": w})
    params = {"w": w}
    # stability: error feedback releases ~1/density accumulated gradients at
    # once, so lr must satisfy lr/density < 2 -> lr 0.05 at density 1/8
    for _ in range(300):
        g = {"w": params["w"] - target}
        g, err, _ = compress_grads(g, err, ccfg)
        params = {"w": params["w"] - 0.05 * g["w"]}
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_int8_roundtrip_bounds(rng):
    g = {"a": jnp.asarray(rng.standard_normal((1000,)) * 5, jnp.float32)}
    out, err, m = compress_grads(g, init_error(g), CompressionConfig(kind="int8"))
    resid = float(jnp.abs(out["a"] + err["a"] - g["a"]).max())
    assert resid < 1e-5   # sent + residual == original (error feedback exact)
    assert m["compression_ratio"] > 3.5
