# NOTE: no XLA_FLAGS here on purpose — tests and benches see the real single
# CPU device; only launch/dryrun.py forces 512 placeholder devices.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
