"""NUMA placement layer: channel affinity + row placement.

The contract under test (ISSUE 5 tentpole):

* the degenerate ``symmetric``/``interleave`` configuration is *bitwise
  identical* to the pre-placement engine across every policy, cache backend,
  and cluster topology (the placement map is the identity and is skipped);
* the row -> (channel-group, rank) mapping is total, and every placed
  request decomposes onto exactly one channel of its affine group
  (property-tested over core counts, affinities, placements, and seeds);
* ``per_core`` affinity really isolates: the contended shared-DRAM scan over
  placed addresses equals running each core's stream through an independent
  ``dram_timing_segmented`` dispatch, finish cycles and row-hit counts
  bitwise (differential fuzz);
* the sweep axes (``channel_affinities`` / ``placements``) are memoized
  correctly — every grid point bit-exact vs an independent ``simulate()``.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from differential import (
    assert_bitwise_equal_results,
    golden_pair,
    make_etrace,
    trace_corpus,
)
from repro.core import (
    MemorySystem,
    OnChipPolicy,
    dlrm_rmc2_small,
    memory_system_for,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core.hardware import CACHE_BACKENDS, CHANNEL_AFFINITIES, PLACEMENTS
from repro.core.memory.dram import (
    DramModel,
    dram_timing_contended,
    dram_timing_segmented,
)
from repro.core.trace import PlacementMap, profile_hot_vectors
from repro.core.workload import EmbeddingOpSpec

_SPEC = EmbeddingOpSpec(num_tables=6, rows_per_table=4000, dim=128,
                        lookups_per_sample=6, dtype_bytes=4)


def _pmap(hw, spec=_SPEC, hot_vecs=None):
    return PlacementMap.from_model(
        DramModel.from_hardware(hw), hw, spec, hot_vecs=hot_vecs
    )


def _vector_lines(rng, nv, lpv=8):
    base = rng.integers(0, _SPEC.num_tables * _SPEC.table_bytes // 512,
                        size=nv).astype(np.int64) * lpv
    return (base[:, None] + np.arange(lpv)[None, :]).reshape(-1)


# --------------------------------------------------------------------------
# Degenerate config: bitwise identity with the pre-placement engine
# --------------------------------------------------------------------------

def test_symmetric_interleave_map_is_identity(rng):
    """place() under symmetric/interleave returns its input bitwise — the
    degenerate path cannot perturb the historical engine by construction."""
    pm = _pmap(tpuv6e())
    assert pm.is_identity
    lines = _vector_lines(rng, 3000)
    placed = pm.place(lines, rng.integers(0, 4, size=lines.size))
    assert placed is lines or np.array_equal(placed, lines)
    # and the MemorySystem skips the map entirely
    ms = MemorySystem.from_hardware(tpuv6e())
    assert ms.placement_map(make_etrace(_SPEC, [4])) is None


@pytest.mark.parametrize("cores,topo", [(1, "private"), (2, "private"), (2, "shared")])
def test_symmetric_interleave_bitexact_per_policy(cores, topo):
    """Explicitly selecting the degenerate placement equals the default
    config bitwise for every policy and cluster topology (golden_pair)."""
    corpus = trace_corpus(spec=_SPEC, batch_sets=((6, 9),), seeds=(0,))
    from repro.core import available_policies

    for policy in sorted(available_policies()):
        hw = tpuv6e().with_policy(
            OnChipPolicy(policy), capacity_bytes=1 << 17
        ).with_cluster(cores, topo)
        hw_explicit = hw.with_placement("symmetric", "interleave")
        golden_pair(
            lambda et, h=hw_explicit: memory_system_for(h).simulate_embedding(et),
            lambda et, h=hw: memory_system_for(h).simulate_embedding(et),
            corpus=corpus,
            label=f"{policy}/{cores}c-{topo}",
        )()


@pytest.mark.parametrize("backend", CACHE_BACKENDS)
def test_symmetric_interleave_bitexact_per_backend(backend):
    """The degenerate placement is invisible under every cache backend
    (Pallas variants in interpret mode on CPU)."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=300, batch_size=2,
                         num_batches=2)
    hw = tpuv6e().with_policy("lru", capacity_bytes=1 << 14)
    hw = hw.with_cache_backend(backend)
    ref = simulate(wl, hw, seed=0, zipf_s=0.9)
    got = simulate(wl, hw.with_placement("symmetric", "interleave"),
                   seed=0, zipf_s=0.9)
    assert_bitwise_equal_results(got, ref, label=backend)


# --------------------------------------------------------------------------
# Property tests: mapping totality + affine routing + conservation
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    cores=st.sampled_from([1, 2, 4, 8, 16]),
    affinity=st.sampled_from(list(CHANNEL_AFFINITIES)),
    placement=st.sampled_from(list(PLACEMENTS)),
    seed=st.integers(0, 2**31 - 1),
)
def test_mapping_total_and_lands_on_affine_channels(cores, affinity, placement, seed):
    """Totality + affinity: every line maps to exactly one placed address,
    and that address decomposes onto a channel of the request's group."""
    rng = np.random.default_rng(seed)
    hw = tpuv6e().with_cluster(cores, "private", "table_hash").with_placement(
        affinity, placement)
    dm = DramModel.from_hardware(hw)
    lines = _vector_lines(rng, 500)
    src = rng.integers(0, cores, size=lines.size).astype(np.int64)
    hot = profile_hot_vectors((lines * 64) // _SPEC.vector_bytes)
    pm = _pmap(hw, hot_vecs=hot if placement == "hot_replicate" else None)

    group = pm.group_of(lines, src)
    assert group.shape == lines.shape            # total: one group per request
    assert np.all((0 <= group) & (group < pm.num_groups))

    placed = pm.place(lines, src)
    assert placed.shape == lines.shape           # total: one home per request
    assert np.all(placed >= 0)
    ch, _bk, _row = dm.decompose(placed)
    for g in range(pm.num_groups):
        m = group == g
        if not np.any(m):
            continue
        affine = set(pm.affine_channels(g).tolist())
        assert set(np.unique(ch[m]).tolist()) <= affine, (g, affinity, placement)
    # injectivity per source: distinct lines never merge (row-hit accounting
    # downstream relies on it)
    for c in range(cores):
        m = src == c
        assert np.unique(placed[m]).size == np.unique(lines[m]).size


@settings(max_examples=10, deadline=None)
@given(cores=st.sampled_from([2, 4]), seed=st.integers(0, 2**31 - 1))
def test_symmetric_conservation_per_core_counts(cores, seed):
    """Under symmetric affinity the per-core attribution is pure accounting:
    per-source access counts sum to the merged total, each source's finish is
    bounded by the segment finish, and the segment finish equals the max."""
    rng = np.random.default_rng(seed)
    dm = DramModel.from_hardware(tpuv6e())
    lines = _vector_lines(rng, 400)
    n = lines.size
    seg = np.sort(rng.integers(0, 2, size=n))
    src = rng.integers(0, cores, size=n)
    res, fin = dram_timing_contended(lines, seg, src, 2, cores, dm)
    merged, fin1 = dram_timing_contended(
        lines, seg, np.zeros(n, dtype=np.int64), 2, 1, dm)
    for s in range(2):
        # same merged stream: per-segment results independent of src tags
        assert_bitwise_equal_results(res[s], merged[s])
        per_src = np.bincount(src[seg == s], minlength=cores)
        assert per_src.sum() == res[s].accesses
        present = per_src > 0
        assert np.all(fin[s][present] > 0)
        assert np.all(fin[s] <= res[s].finish_cycle)
        assert fin[s].max() == res[s].finish_cycle == fin1[s, 0]


# --------------------------------------------------------------------------
# Differential fuzz: per_core isolation == independent per-core timing
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(cores=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_per_core_contended_equals_private_group_segmented(cores, seed):
    """With per_core affinity, cores' placed streams touch disjoint channel
    groups, so the contended shared-DRAM dispatch must equal running each
    core's own stream through an independent single-core
    ``dram_timing_segmented`` — finish cycles and row-hit counts bitwise."""
    rng = np.random.default_rng(seed)
    hw = tpuv6e().with_cluster(cores, "private", "table_hash").with_placement(
        "per_core", "interleave")
    dm = DramModel.from_hardware(hw)
    pm = _pmap(hw)
    nv = 600
    lines = _vector_lines(rng, nv)
    seg = np.repeat(np.sort(rng.integers(0, 2, size=nv)), 8)
    src = np.repeat(rng.integers(0, cores, size=nv), 8)
    placed = pm.place(lines, src)

    res, fin = dram_timing_contended(placed, seg, src, 2, cores, dm)
    alone = [dram_timing_segmented(placed[src == c], seg[src == c], 2, dm)
             for c in range(cores)]
    for s in range(2):
        for c in range(cores):
            if np.any((src == c) & (seg == s)):
                assert fin[s, c] == alone[c][s].finish_cycle, (s, c)
            else:
                assert fin[s, c] == 0.0
        assert res[s].row_hits == sum(a[s].row_hits for a in alone)
        assert res[s].row_misses == sum(a[s].row_misses for a in alone)
        assert res[s].finish_cycle == max(a[s].finish_cycle for a in alone)


# --------------------------------------------------------------------------
# Sweep axes + memoization keys
# --------------------------------------------------------------------------

def test_sweep_placement_axes_bitexact_vs_simulate():
    """Every (affinity, placement) grid point equals an independent
    simulate() with the same config — the memo key carries both axes."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    base = tpuv6e().with_cluster(2, "private", "table_hash")
    sr = sweep(wl, base, policies=("spm", "lru"), capacities=(1 << 16,),
               ways=(4,), zipf_s=1.0, seed=0,
               channel_affinities=("symmetric", "per_core", "per_table"),
               placements=("interleave", "table_rank", "hot_replicate"))
    assert sr.num_configs == 2 * 3 * 3
    labels = {e.config.label for e in sr.entries}
    assert len(labels) == sr.num_configs
    for e in sr.entries:
        c = e.config
        hw = base.with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        ).with_placement(c.channel_affinity, c.placement)
        ref = simulate(wl, hw, seed=0, zipf_s=c.zipf_s)
        assert_bitwise_equal_results(e.result, ref, label=c.label)
    # the axes must actually matter: symmetric and per_core SPM points
    # cannot share DRAM timing on this contended workload
    by_aff = {
        e.config.channel_affinity: e.result.embedding_cycles
        for e in sr.entries
        if e.config.policy == "spm" and e.config.placement == "interleave"
    }
    assert by_aff["symmetric"] != by_aff["per_core"]


def test_single_core_affinity_collapses_and_memoizes():
    """With one core every affinity is a single channel group, so the sweep
    canonicalizes the memo key — all affinity values of an nc=1 grid point
    are bitwise identical to symmetric AND to independent simulate()."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    sr = sweep(wl, tpuv6e(), policies=("lru",), capacities=(1 << 16,),
               ways=(4,), zipf_s=1.0, seed=0,
               channel_affinities=("symmetric", "per_core", "per_table"),
               placements=("interleave", "table_rank"))
    by = {(e.config.channel_affinity, e.config.placement): e.result
          for e in sr.entries}
    for plc in ("interleave", "table_rank"):
        for aff in ("per_core", "per_table"):
            assert_bitwise_equal_results(by[(aff, plc)], by[("symmetric", plc)],
                                         label=f"{aff}/{plc}")
        hw = tpuv6e().with_policy("lru", capacity_bytes=1 << 16, ways=4
                                  ).with_placement("per_core", plc)
        assert_bitwise_equal_results(
            by[("per_core", plc)], simulate(wl, hw, seed=0, zipf_s=1.0))


def test_single_core_placement_rides_batched_classification():
    """On a 1-core grid the vmapped same-policy classification batching still
    applies; placement happens per memo key downstream of it — every grid
    point bit-exact vs independent simulate(), batched or not."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    base = tpuv6e().with_placement("symmetric", "table_rank")
    kw = dict(policies=("lru",), capacities=(1 << 16, 1 << 17, 1 << 18),
              ways=(4,), zipf_s=1.0, seed=0)
    a = sweep(wl, base, batch_scans=True, **kw)
    b = sweep(wl, base, batch_scans=False, **kw)
    assert_bitwise_equal_results(a, b)
    for e in a.entries:
        c = e.config
        assert c.placement == "table_rank"
        hw = base.with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        )
        assert_bitwise_equal_results(
            e.result, simulate(wl, hw, seed=0, zipf_s=c.zipf_s), label=c.label
        )


def test_effective_placement_degeneracy_collapse(rng):
    """table_rank with a single rank AND a single table is provably the plain
    interleave transform (PlacementMap.effective_placement), and with one
    channel group that makes it the exact identity."""
    from dataclasses import replace

    spec1 = EmbeddingOpSpec(num_tables=1, rows_per_table=4000, dim=128,
                            lookups_per_sample=6, dtype_bytes=4)
    base = tpuv6e()
    hw1 = replace(base, offchip=replace(base.offchip, banks_per_channel=1))

    pm = _pmap(hw1.with_placement("symmetric", "table_rank"), spec=spec1)
    assert pm.effective_placement == "interleave"
    assert pm.is_identity
    lines = rng.integers(0, spec1.table_bytes // 64, size=4000).astype(np.int64)
    assert np.array_equal(pm.place(lines), lines)

    # multi-group: table_rank still equals interleave under the SAME groups
    hw_g = hw1.with_cluster(2, "private", "table_hash").with_placement(
        "per_core", "table_rank")
    src = rng.integers(0, 2, size=lines.size).astype(np.int64)
    pm_tr = _pmap(hw_g, spec=spec1)
    pm_il = _pmap(hw_g.with_placement("per_core", "interleave"), spec=spec1)
    assert pm_tr.effective_placement == "interleave"
    assert not pm_tr.is_identity
    assert np.array_equal(pm_tr.place(lines, src), pm_il.place(lines, src))

    # but each degeneracy alone is NOT enough: two tables or two ranks keep
    # the table_rank transform distinct from interleave
    assert _pmap(hw1.with_placement("symmetric", "table_rank")
                 ).effective_placement == "table_rank"
    assert _pmap(base.with_placement("symmetric", "table_rank"), spec=spec1
                 ).effective_placement == "table_rank"
    # hot_replicate with an empty hot set is exactly table_rank
    assert _pmap(base.with_placement("symmetric", "hot_replicate"), spec=spec1,
                 hot_vecs=np.zeros(0, dtype=np.int64)
                 ).effective_placement == "table_rank"


def test_sweep_collapses_degenerate_table_rank_onto_base_entry(monkeypatch):
    """A placement config whose transform is the identity for the topology
    (table_rank, one rank, one table) must collapse onto the base-grid memo
    entry — one DRAM request for both grid points, bitwise-equal results."""
    import importlib
    from dataclasses import replace

    sweep_mod = importlib.import_module("repro.core.sweep")

    wl = dlrm_rmc2_small(num_tables=1, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    base = tpuv6e()
    hw1 = replace(base, offchip=replace(base.offchip, banks_per_channel=1))

    calls = []
    orig = sweep_mod.dram_timing_many
    monkeypatch.setattr(
        sweep_mod, "dram_timing_many",
        lambda reqs, batch=True: calls.append(len(reqs)) or orig(reqs, batch=batch),
    )
    sr = sweep(wl, hw1, policies=("lru",), capacities=(1 << 16,), ways=(4,),
               zipf_s=1.0, seed=0, placements=("interleave", "table_rank"))
    assert sr.num_configs == 2
    assert sum(calls) == 1          # ONE memo key -> one deferred request
    by = {e.config.placement: e.result for e in sr.entries}
    assert_bitwise_equal_results(by["table_rank"], by["interleave"])
    hw_tr = hw1.with_policy("lru", capacity_bytes=1 << 16, ways=4
                            ).with_placement("symmetric", "table_rank")
    assert_bitwise_equal_results(
        by["table_rank"], simulate(wl, hw_tr, seed=0, zipf_s=1.0))


def test_placement_siblings_share_classification(monkeypatch):
    """Grid points differing only in (affinity, placement) classify ONCE per
    placement-invariant class key — the NUMA axes only remap miss addresses
    downstream (classify_for_pending / pending_from split)."""
    from repro.core.memory.system import MultiCoreMemorySystem

    count = {"n": 0}
    orig = MultiCoreMemorySystem.classify_for_pending

    def spy(self, *a, **k):
        count["n"] += 1
        return orig(self, *a, **k)

    monkeypatch.setattr(MultiCoreMemorySystem, "classify_for_pending", spy)
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=1500, dim=128,
                         lookups=3, batch_size=6, num_batches=2)
    base = tpuv6e().with_cluster(2, "private", "table_hash")
    sr = sweep(wl, base, policies=("spm", "lru"), capacities=(1 << 16,),
               ways=(4,), zipf_s=1.0, seed=0,
               channel_affinities=("symmetric", "per_core", "per_table"),
               placements=("interleave", "table_rank", "hot_replicate"))
    assert sr.num_configs == 2 * 3 * 3
    # one classification per policy class key, not one per (aff, plc) point
    assert count["n"] == 2


def test_dram_timing_many_placement_edge_cases(rng):
    """Satellite: batched dram_timing_many over placement-transformed
    requests vs the unbatched reference path (batch=False), bitwise —
    covering empty per-channel groups (restrictive affinity leaves 15/16
    channel groups untouched), single-request buckets, and an all-hot
    hot_replicate trace (every line lands in the replica region)."""
    from repro.core.memory.dram import DramRequest, dram_timing_many

    hw = tpuv6e().with_cluster(16, "private", "table_hash").with_placement(
        "per_core", "interleave")
    dm = DramModel.from_hardware(hw)

    reqs = []
    # (a) restrictive affinity: every request from ONE core -> one channel
    #     group busy, all other (segment, channel) rows empty in the scan
    pm = _pmap(hw)
    lines = _vector_lines(rng, 300)
    src = np.zeros(lines.size, dtype=np.int64)
    seg = np.sort(rng.integers(0, 2, size=lines.size))
    reqs.append(DramRequest(pm.place(lines, src), seg, src, 2, 16, dm))
    # (b) single-request buckets: 1-line and 1-vector requests
    one = _vector_lines(rng, 1)[:1]
    z1 = np.zeros(1, dtype=np.int64)
    reqs.append(DramRequest(one, z1, z1, 1, 1, dm))
    vec = _vector_lines(rng, 1)
    zv = np.zeros(vec.size, dtype=np.int64)
    reqs.append(DramRequest(vec, zv, zv, 1, 1, dm))
    # (c) all-hot hot_replicate: the hot set covers every vector in the trace
    hw_hot = hw.with_placement("per_core", "hot_replicate")
    lines_h = _vector_lines(rng, 400)
    all_vecs = np.unique((lines_h * 64) // _SPEC.vector_bytes)
    pm_hot = _pmap(hw_hot, hot_vecs=all_vecs)
    src_h = rng.integers(0, 16, size=lines_h.size).astype(np.int64)
    placed_h = pm_hot.place(lines_h, src_h)
    seg_h = np.sort(rng.integers(0, 2, size=lines_h.size))
    reqs.append(DramRequest(placed_h, seg_h, src_h, 2, 16, dm))

    batched = dram_timing_many(reqs, batch=True)
    ref = dram_timing_many(reqs, batch=False)
    for (rb, fb), (rr, fr) in zip(batched, ref):
        assert_bitwise_equal_results(rb, rr)
        assert np.array_equal(fb, fr)


def test_hot_replicate_deterministic_and_conserves_accesses():
    """hot_replicate profiles its hot set from the trace deterministically:
    repeated runs are bitwise identical, and placement never changes HOW MUCH
    traffic there is — only where it lands."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=4000, dim=128,
                         lookups=6, batch_size=12, num_batches=2)
    hw = tpuv6e().with_policy("lru", capacity_bytes=1 << 17).with_cluster(
        2, "private", "table_hash").with_placement("per_core", "hot_replicate")
    a = simulate(wl, hw, seed=0, zipf_s=1.05)
    b = simulate(wl, hw, seed=0, zipf_s=1.05)
    assert_bitwise_equal_results(a, b)
    ref = simulate(wl, hw.with_placement("symmetric", "interleave"),
                   seed=0, zipf_s=1.05)
    assert a.cache_hits == ref.cache_hits
    assert a.cache_misses == ref.cache_misses
    assert a.offchip_reads == ref.offchip_reads
    assert (a.batches[0].dram_row_hits + a.batches[0].dram_row_misses
            == ref.batches[0].dram_row_hits + ref.batches[0].dram_row_misses)


def test_per_core_affinity_reduces_contention_with_table_hash():
    """The headline claim (examples/placement_contention.py, smoke-sized):
    per_core affinity + table_hash sharding strictly lowers contended
    embedding cycles vs symmetric on a balanced all-miss workload."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=20000, dim=128,
                         lookups=8, batch_size=32, num_batches=2)
    hw = tpuv6e().with_policy(OnChipPolicy.SPM).with_cluster(
        2, "private", "table_hash")
    sym = simulate(wl, hw, seed=0, zipf_s=1.05)
    pc = simulate(wl, hw.with_placement("per_core", "interleave"),
                  seed=0, zipf_s=1.05)
    assert pc.embedding_cycles < sym.embedding_cycles


# --------------------------------------------------------------------------
# Validation
# --------------------------------------------------------------------------

def test_table_rank_never_shares_a_row_across_tables():
    """Regression: the per-table q-span is row-aligned, so two tables homed
    to the same rank can never share the DRAM row straddling their boundary
    (an unaligned span counted a spurious cross-table row hit per boundary —
    in exactly the configs table_rank claims to isolate)."""
    # rows_per_table chosen so table_bytes // interleave_bytes + 2 is NOT a
    # multiple of blocks_per_row; group_size=1 puts every block of a table on
    # one (channel, bank) where boundary rows would collide.
    spec = EmbeddingOpSpec(num_tables=8, rows_per_table=4001, dim=128,
                           lookups_per_sample=4, dtype_bytes=4)
    hw = tpuv6e().with_cluster(16, "private", "table_hash").with_placement(
        "per_core", "table_rank")
    dm = DramModel.from_hardware(hw)
    pm = PlacementMap.from_model(dm, hw, spec)
    # every line of the address space boundary region of each table pair
    lpv = spec.vector_bytes // 64
    rows = np.arange(spec.rows_per_table * spec.num_tables, dtype=np.int64)
    lines = (rows[:, None] * lpv + np.arange(lpv)[None, :]).reshape(-1)
    # per_core routing: give each line its table's owning core (table_hash)
    from repro.core.trace import table_core_of
    src = table_core_of(pm.table_of(lines), hw.num_cores).astype(np.int64)
    placed = pm.place(lines, src)
    ch, bk, row = dm.decompose(placed)
    key = (ch.astype(np.int64) * dm.banks_per_channel + bk) * (2**32) + row
    t = pm.table_of(lines)
    order = np.argsort(key, kind="stable")
    same_row = key[order][1:] == key[order][:-1]
    assert np.all(t[order][1:][same_row] == t[order][:-1][same_row])


def test_with_placement_validation():
    with pytest.raises(ValueError, match="channel affinity"):
        tpuv6e().with_placement("per_rank")
    with pytest.raises(ValueError, match="placement"):
        tpuv6e().with_placement(placement="hot")
    hw = tpuv6e().with_placement("per_core", "table_rank")
    assert hw.channel_affinity == "per_core"
    assert hw.placement == "table_rank"
    # per_core routing without source-core tags must fail loudly, not home
    # everything to group 0 (regression)
    pm = _pmap(hw.with_cluster(4, "private", "table_hash"))
    with pytest.raises(ValueError, match="source-core"):
        pm.group_of(np.arange(10, dtype=np.int64), None)


def test_uneven_channel_split_rejected():
    """per_core affinity needs channels % num_cores == 0 — checked when the
    placement map is built (the cluster shape may change after
    with_placement)."""
    wl = dlrm_rmc2_small(num_tables=3, rows_per_table=500, lookups=2,
                         batch_size=4)
    hw = tpuv6e().with_cluster(3, "private", "table_hash").with_placement(
        "per_core", "interleave")
    with pytest.raises(ValueError, match="divisible"):
        simulate(wl, hw, seed=0)
