"""DRAM model vs sequential golden reference; fast path tolerance; on-chip
policy semantics (SPM / cache / pinning)."""
import numpy as np
import pytest

from repro.core.hardware import OnChipPolicy, tpuv6e
from repro.core.memory.dram import (
    DramModel,
    estimate_dram_fast,
    simulate_dram,
    simulate_dram_contended,
)
from repro.core.memory.golden_dram import golden_dram
from repro.core.memory.policies import profile_hot_lines, run_policy
from repro.core.trace import (
    AddressTrace,
    expand_trace,
    generate_zipf_trace,
    translate,
)
from repro.core.workload import EmbeddingOpSpec


@pytest.fixture
def dm():
    return DramModel.from_hardware(tpuv6e())


def _vec_trace(rng, n_vec, space, lpv=8):
    base = rng.integers(0, space, size=n_vec) * lpv
    return (base[:, None] + np.arange(lpv)[None, :]).reshape(-1)


@pytest.mark.parametrize("pattern", ["random", "stream", "zipf"])
def test_dram_engine_matches_golden(pattern, dm, rng):
    if pattern == "stream":
        lines = np.arange(20000)
    elif pattern == "zipf":
        v = generate_zipf_trace(2500, 100_000, 1.0, seed=3)
        lines = (v[:, None] * 8 + np.arange(8)[None, :]).reshape(-1)
    else:
        lines = _vec_trace(rng, 2500, 500_000)
    ours = simulate_dram(lines, dm)
    gold = golden_dram(lines, dm)
    assert ours.row_hits == gold.row_hits
    # f32 scan accumulation vs python float: allow fp drift only
    assert abs(ours.finish_cycle - gold.finish_cycle) / gold.finish_cycle < 1e-4


def test_dram_fast_path_tolerance(dm, rng):
    lines = _vec_trace(rng, 5000, 1_000_000)
    det = simulate_dram(lines, dm)
    fast = estimate_dram_fast(lines, dm)
    assert abs(fast.finish_cycle - det.finish_cycle) / det.finish_cycle < 0.10
    assert fast.row_hits == det.row_hits  # transition counting is exact


def test_dram_streaming_beats_random(dm, rng):
    stream = simulate_dram(np.arange(20000), dm)
    rand = simulate_dram(_vec_trace(rng, 2500, 10_000_000), dm)
    assert stream.finish_cycle < rand.finish_cycle
    assert stream.row_hit_rate > rand.row_hit_rate


@pytest.mark.parametrize("num_sources", [1, 3])
@pytest.mark.parametrize("pattern", ["vectors", "random"])
def test_dram_device_aggregates_match_host_reference(
    pattern, num_sources, dm, rng
):
    """In-scan carry aggregates vs independent host re-derivation, bitwise.

    The host mode replays the same IEEE f32 op chains from the per-chunk scan
    outputs with a separate implementation — any drift in the device-resident
    bookkeeping (latency chain, row-hit fold, completion maxima, per-source
    finish) shows up as an exact-compare failure here.
    """
    from differential import assert_bitwise_equal_results

    if pattern == "vectors":
        lines = _vec_trace(rng, 6000, 50_000)
    else:
        lines = rng.integers(0, 400_000, size=48_000)
    num_segments = 4
    seg = np.sort(rng.integers(0, num_segments, size=lines.size))
    seg[seg == 2] = 3                     # leave one segment empty
    src = rng.integers(0, num_sources, size=lines.size)
    dev = simulate_dram_contended(
        lines, seg, src, num_segments, num_sources, dm, aggregate="device")
    host = simulate_dram_contended(
        lines, seg, src, num_segments, num_sources, dm, aggregate="host")
    assert_bitwise_equal_results(dev, host)


def test_dram_contended_tiny_and_empty(dm):
    """Degenerate shapes: empty trace, one access, one chunk per mode."""
    from differential import assert_bitwise_equal_results

    empty = np.zeros(0, dtype=np.int64)
    res, fin = simulate_dram_contended(empty, empty, empty, 2, 2, dm)
    assert all(r.accesses == 0 for r in res) and not fin.any()
    for lines in ([5], [5, 5, 5], list(range(8)), [9, 1000, 9]):
        arr = np.asarray(lines, dtype=np.int64)
        z = np.zeros(arr.size, dtype=np.int64)
        assert_bitwise_equal_results(
            simulate_dram_contended(arr, z, z, 1, 1, dm, aggregate="device"),
            simulate_dram_contended(arr, z, z, 1, 1, dm, aggregate="host"),
        )


def test_radix_argsort_matches_numpy_stable(rng):
    """_argsort_stable must be THE stable permutation for every key width
    (single uint16 pass, two-pass, three-pass) including heavy ties."""
    from repro.core.memory.dram import _argsort_stable

    for kmax in (1, 100, 1 << 15, (1 << 16) - 1, 1 << 16, 1 << 20,
                 1 << 31, 1 << 40, 1 << 50):
        for n in (0, 1, 7, 5000):
            key = rng.integers(0, kmax + 1, n).astype(np.int64)
            np.testing.assert_array_equal(
                _argsort_stable(key), np.argsort(key, kind="stable"),
                err_msg=f"kmax={kmax} n={n}")
    few = rng.integers(0, 3, 4096).astype(np.int64) * (1 << 33)
    np.testing.assert_array_equal(
        _argsort_stable(few), np.argsort(few, kind="stable"))


def test_dram_contended_rejects_unknown_aggregate(dm):
    with pytest.raises(ValueError, match="aggregate"):
        simulate_dram_contended(
            np.array([1]), np.array([0]), np.array([0]), 1, 1, dm,
            aggregate="gpu")


def _atrace(rng, hw, n=2000):
    spec = EmbeddingOpSpec(num_tables=4, rows_per_table=1000, dim=128,
                           lookups_per_sample=10, dtype_bytes=4)
    tr = generate_zipf_trace(n, 1000, 1.0, seed=1)
    full = expand_trace(tr, spec, batch_size=n // 40, seed=2)
    return translate(full, spec, hw.onchip.line_bytes), spec


def test_spm_counts(rng):
    hw = tpuv6e()
    at, spec = _atrace(rng, hw)
    out = run_policy(at, hw)
    n = len(at)
    assert out.offchip_reads == n            # everything fetched off-chip
    assert out.onchip_reads == n
    assert out.onchip_writes == n
    assert not out.hits.any()
    assert abs(out.onchip_ratio - 2 / 3) < 1e-9


def test_cache_policy_reduces_offchip(rng):
    hw = tpuv6e()
    at, spec = _atrace(rng, hw)
    spm = run_policy(at, hw)
    lru = run_policy(at, hw.with_policy(OnChipPolicy.LRU))
    assert lru.offchip_reads < spm.offchip_reads
    assert lru.onchip_ratio > spm.onchip_ratio


def test_pinning_hits_hot_lines(rng):
    hw = tpuv6e().with_policy(OnChipPolicy.PINNING)
    at, spec = _atrace(rng, hw, n=4000)
    out = run_policy(at, hw)
    # hottest lines pinned -> hit rate at least the hot mass share
    assert out.hit_rate > 0.3
    # pinned set within capacity
    hot = profile_hot_lines(at.lines, hw.onchip.num_lines)
    assert len(hot) <= hw.onchip.num_lines


def test_pinning_respects_capacity(rng):
    lines = rng.integers(0, 100_000, size=5000)
    hot = profile_hot_lines(lines, 64)
    assert len(hot) <= 64
    assert np.all(np.diff(hot) > 0)  # sorted unique
