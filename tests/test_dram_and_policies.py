"""DRAM model vs sequential golden reference; fast path tolerance; on-chip
policy semantics (SPM / cache / pinning)."""
import numpy as np
import pytest

from repro.core.hardware import OnChipPolicy, tpuv6e
from repro.core.memory.dram import DramModel, estimate_dram_fast, simulate_dram
from repro.core.memory.golden_dram import golden_dram
from repro.core.memory.policies import profile_hot_lines, run_policy
from repro.core.trace import (
    AddressTrace,
    expand_trace,
    generate_zipf_trace,
    translate,
)
from repro.core.workload import EmbeddingOpSpec


@pytest.fixture
def dm():
    return DramModel.from_hardware(tpuv6e())


def _vec_trace(rng, n_vec, space, lpv=8):
    base = rng.integers(0, space, size=n_vec) * lpv
    return (base[:, None] + np.arange(lpv)[None, :]).reshape(-1)


@pytest.mark.parametrize("pattern", ["random", "stream", "zipf"])
def test_dram_engine_matches_golden(pattern, dm, rng):
    if pattern == "stream":
        lines = np.arange(20000)
    elif pattern == "zipf":
        v = generate_zipf_trace(2500, 100_000, 1.0, seed=3)
        lines = (v[:, None] * 8 + np.arange(8)[None, :]).reshape(-1)
    else:
        lines = _vec_trace(rng, 2500, 500_000)
    ours = simulate_dram(lines, dm)
    gold = golden_dram(lines, dm)
    assert ours.row_hits == gold.row_hits
    # f32 scan accumulation vs python float: allow fp drift only
    assert abs(ours.finish_cycle - gold.finish_cycle) / gold.finish_cycle < 1e-4


def test_dram_fast_path_tolerance(dm, rng):
    lines = _vec_trace(rng, 5000, 1_000_000)
    det = simulate_dram(lines, dm)
    fast = estimate_dram_fast(lines, dm)
    assert abs(fast.finish_cycle - det.finish_cycle) / det.finish_cycle < 0.10
    assert fast.row_hits == det.row_hits  # transition counting is exact


def test_dram_streaming_beats_random(dm, rng):
    stream = simulate_dram(np.arange(20000), dm)
    rand = simulate_dram(_vec_trace(rng, 2500, 10_000_000), dm)
    assert stream.finish_cycle < rand.finish_cycle
    assert stream.row_hit_rate > rand.row_hit_rate


def _atrace(rng, hw, n=2000):
    spec = EmbeddingOpSpec(num_tables=4, rows_per_table=1000, dim=128,
                           lookups_per_sample=10, dtype_bytes=4)
    tr = generate_zipf_trace(n, 1000, 1.0, seed=1)
    full = expand_trace(tr, spec, batch_size=n // 40, seed=2)
    return translate(full, spec, hw.onchip.line_bytes), spec


def test_spm_counts(rng):
    hw = tpuv6e()
    at, spec = _atrace(rng, hw)
    out = run_policy(at, hw)
    n = len(at)
    assert out.offchip_reads == n            # everything fetched off-chip
    assert out.onchip_reads == n
    assert out.onchip_writes == n
    assert not out.hits.any()
    assert abs(out.onchip_ratio - 2 / 3) < 1e-9


def test_cache_policy_reduces_offchip(rng):
    hw = tpuv6e()
    at, spec = _atrace(rng, hw)
    spm = run_policy(at, hw)
    lru = run_policy(at, hw.with_policy(OnChipPolicy.LRU))
    assert lru.offchip_reads < spm.offchip_reads
    assert lru.onchip_ratio > spm.onchip_ratio


def test_pinning_hits_hot_lines(rng):
    hw = tpuv6e().with_policy(OnChipPolicy.PINNING)
    at, spec = _atrace(rng, hw, n=4000)
    out = run_policy(at, hw)
    # hottest lines pinned -> hit rate at least the hot mass share
    assert out.hit_rate > 0.3
    # pinned set within capacity
    hot = profile_hot_lines(at.lines, hw.onchip.num_lines)
    assert len(hot) <= hw.onchip.num_lines


def test_pinning_respects_capacity(rng):
    lines = rng.integers(0, 100_000, size=5000)
    hot = profile_hot_lines(lines, 64)
    assert len(hot) <= 64
    assert np.all(np.diff(hot) > 0)  # sorted unique
