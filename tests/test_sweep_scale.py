"""Scaling layer of the DSE engine: explicit config lists, device-sharded
evaluation, checkpointed kill-and-resume, degenerate memo-key collapses, and
the successive-halving Pareto search — every path bitwise identical to the
plain single-pass sweep (the differential comparator enforces it)."""
import json
import os
import zlib

import pytest

from differential import assert_bitwise_equal_results
from repro.core import (
    OnChipPolicy,
    SweepCheckpoint,
    dlrm_rmc2_small,
    grid_configs,
    search,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core.search import nondominated_ranks, pareto_front
from repro.core.sweep_ckpt import fingerprint_digest

POLICIES = ("spm", "lru", "srrip", "pinning")
CAPACITIES = (1 << 16, 1 << 17, 1 << 18)
WAYS = (4, 8)
GRID = dict(policies=POLICIES, capacities=CAPACITIES, ways=WAYS,
            zipf_s=0.9, seed=0)


@pytest.fixture(scope="module")
def small_wl():
    return dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                           lookups=4, batch_size=8, num_batches=2)


@pytest.fixture(scope="module")
def grid_result(small_wl):
    return sweep(small_wl, tpuv6e(), **GRID)


# --------------------------------------------------------------------------
# Explicit config lists
# --------------------------------------------------------------------------

def test_grid_configs_matches_axes_sweep(grid_result, small_wl):
    """sweep(configs=grid_configs(...)) must be the axes sweep, bitwise and
    in the same entry order."""
    cfgs = grid_configs(small_wl, tpuv6e(), policies=POLICIES,
                        capacities=CAPACITIES, ways=WAYS, zipf_s=0.9)
    assert [e.config for e in grid_result.entries] == cfgs
    got = sweep(small_wl, tpuv6e(), configs=cfgs, seed=0)
    assert_bitwise_equal_results(grid_result, got, "configs= path")


def test_configs_subset_and_order_preserved(grid_result, small_wl):
    """An arbitrary subset keeps ITS order and each entry stays bitwise
    equal to the corresponding full-grid entry."""
    picks = [grid_result.entries[i] for i in (17, 3, 11, 3, 0)]
    got = sweep(small_wl, tpuv6e(), configs=[e.config for e in picks], seed=0)
    assert [e.config for e in got.entries] == [e.config for e in picks]
    for want, have in zip(picks, got.entries):
        assert not want.result.diff(have.result), want.config.label


def test_configs_unknown_workload_rejected(small_wl):
    cfgs = grid_configs(small_wl, tpuv6e(), policies=("spm",), zipf_s=0.9)
    bad = [c.__class__(**{**c.__dict__, "workload": "nope"}) for c in cfgs]
    with pytest.raises(ValueError, match="unknown workload"):
        sweep(small_wl, tpuv6e(), configs=bad, seed=0)


# --------------------------------------------------------------------------
# Degenerate memo-key collapses (satellite: canonicalization)
# --------------------------------------------------------------------------

def test_spm_collapses_to_one_memo_key(small_wl):
    """SPM reads neither capacity nor ways: the whole spm sub-grid is ONE
    memo key, and the collapse is observable + bitwise vs simulate()."""
    sr = sweep(small_wl, tpuv6e(), policies=("spm",), capacities=CAPACITIES,
               ways=WAYS, zipf_s=0.9, seed=0)
    assert sr.num_configs == len(CAPACITIES) * len(WAYS)
    assert sr.distinct_memo_keys == 1
    assert len({e.memo_key for e in sr.entries}) == 1
    ref = simulate(small_wl, tpuv6e().with_policy(OnChipPolicy("spm")),
                   seed=0, zipf_s=0.9)
    for e in sr.entries:
        assert not e.result.diff(ref), e.config.label


def test_pinning_capacity_saturation_collapse(small_wl):
    """Capacities at/above the slice's line footprint pin EVERY line —
    provably identical classification — so they share one canonical memo
    key, and every entry stays bitwise vs independent simulate()."""
    caps = (1 << 12, 4 << 20, 16 << 20)     # tiny + two saturating
    sr = sweep(small_wl, tpuv6e(), policies=("pinning",), capacities=caps,
               ways=(4, 8), zipf_s=0.9, seed=0)
    # ways always collapse for pinning (sensitive_params); the two big
    # capacities collapse onto the saturation marker: 2 keys, not 3 (or 6).
    assert sr.distinct_memo_keys == 2
    sat_keys = {e.memo_key for e in sr.entries
                if e.config.capacity_bytes >= (4 << 20)}
    assert len(sat_keys) == 1
    assert any("cap_saturated" in k for k in sat_keys)
    for e in sr.entries:
        c = e.config
        hw = tpuv6e().with_policy(OnChipPolicy("pinning"),
                                  capacity_bytes=c.capacity_bytes, ways=c.ways)
        ref = simulate(small_wl, hw, seed=0, zipf_s=0.9)
        assert not e.result.diff(ref), c.label


def test_saturation_not_applied_below_footprint(small_wl):
    """A capacity below the footprint must NOT collapse (the pinned top-K
    differs per capacity)."""
    sr = sweep(small_wl, tpuv6e(), policies=("pinning",),
               capacities=(1 << 12, 1 << 13), ways=(4,), zipf_s=0.9, seed=0)
    assert sr.distinct_memo_keys == 2


# --------------------------------------------------------------------------
# Sharded evaluation (multi-shard on however many devices this host has;
# the 8-device run lives in the dse-scale CI job / scripts/dse_scale_smoke)
# --------------------------------------------------------------------------

def test_sharded_sweep_bitwise_equal(grid_result, small_wl):
    got = sweep(small_wl, tpuv6e(), devices=4, **GRID)
    assert got.sharded and got.device_count >= 1
    assert_bitwise_equal_results(grid_result, got, "sharded")


def test_shard_partition_keeps_class_groups_whole():
    from repro.distributed.sweep_shard import partition_by_class_key

    items = {("k", i, p): (None, ("ck", i % 3)) for i in range(9)
             for p in ("a", "b")}
    parts = partition_by_class_key(items, 4)
    assert sum(len(p) for p in parts) == len(items)
    for ck in range(3):
        owners = [i for i, p in enumerate(parts)
                  if any(v[1] == ("ck", ck) for v in p.values())]
        assert len(owners) == 1, f"class group {ck} split across {owners}"
    # Deterministic: same input -> same partition.
    assert parts == partition_by_class_key(dict(items), 4)


# --------------------------------------------------------------------------
# Checkpointed resumability (+ corruption satellite)
# --------------------------------------------------------------------------

def _ckpt_grid(wl, hw, path, cadence=2, **extra):
    return sweep(wl, hw, checkpoint=SweepCheckpoint(path, cadence=cadence)
                 if cadence else path, **GRID, **extra)


def test_checkpoint_resume_bitwise(grid_result, small_wl, tmp_path):
    p = str(tmp_path / "sweep.ckpt")
    first = _ckpt_grid(small_wl, tpuv6e(), p)
    assert_bitwise_equal_results(grid_result, first, "checkpointed run")
    resumed = _ckpt_grid(small_wl, tpuv6e(), p)
    assert resumed.resumed_keys == resumed.distinct_memo_keys
    assert_bitwise_equal_results(grid_result, resumed, "resumed run")


class _KillAfter(SweepCheckpoint):
    """Simulated preemption: die after N journal rounds (the journaled
    rounds are already on disk, exactly like a SIGKILL between rounds)."""

    def __init__(self, path, cadence, rounds):
        super().__init__(path, cadence=cadence)
        self._rounds = rounds

    def record(self, slice_id, results):
        if self._rounds <= 0:
            raise KeyboardInterrupt("simulated preemption")
        self._rounds -= 1
        super().record(slice_id, results)


def test_kill_and_resume_bitwise(grid_result, small_wl, tmp_path):
    """Acceptance criterion: a sweep killed mid-run resumes to a bitwise-
    identical SweepResult, re-evaluating only the unfinished keys."""
    p = str(tmp_path / "killed.ckpt")
    ck = _KillAfter(p, cadence=2, rounds=2)
    with pytest.raises(KeyboardInterrupt):
        sweep(small_wl, tpuv6e(), checkpoint=ck, **GRID)
    ck.close()
    resumed = sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    assert 0 < resumed.resumed_keys < resumed.distinct_memo_keys
    assert_bitwise_equal_results(grid_result, resumed, "kill+resume")


def test_truncated_journal_line_reevaluated(grid_result, small_wl, tmp_path):
    """Satellite: a torn tail (partial write at kill time) must be detected
    and its keys re-evaluated — never silently skipped or half-restored."""
    p = str(tmp_path / "torn.ckpt")
    sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    raw = open(p, "rb").read()
    lines = raw.splitlines(keepends=True)
    assert len(lines) >= 4
    torn = b"".join(lines[:-2]) + lines[-2][: len(lines[-2]) // 2]
    open(p, "wb").write(torn)
    resumed = sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    # The torn key (+ the dropped complete record's key, if any) re-ran.
    assert resumed.resumed_keys < resumed.distinct_memo_keys
    assert_bitwise_equal_results(grid_result, resumed, "torn-tail resume")
    # The rewritten journal is valid again: full restore on the next open.
    again = sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    assert again.resumed_keys == again.distinct_memo_keys


def test_corrupt_crc_line_truncates_tail(grid_result, small_wl, tmp_path):
    """Bit-rot inside a line (CRC mismatch) drops that line AND everything
    after it — journal replay must never resync past a corrupt record."""
    p = str(tmp_path / "crc.ckpt")
    sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    lines = open(p, "rb").read().splitlines(keepends=True)
    mid = len(lines) // 2
    corrupted = bytearray(lines[mid])
    corrupted[10] ^= 0xFF
    open(p, "wb").write(b"".join(lines[:mid]) + bytes(corrupted)
                        + b"".join(lines[mid + 1:]))
    resumed = sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    assert resumed.resumed_keys <= mid - 1   # header + keys before the flip
    assert_bitwise_equal_results(grid_result, resumed, "crc-corrupt resume")


def test_fingerprint_mismatch_raises(small_wl, tmp_path):
    """Resuming against a different sweep spec must refuse, not mix stats."""
    p = str(tmp_path / "fp.ckpt")
    sweep(small_wl, tpuv6e(), checkpoint=p, **GRID)
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        sweep(small_wl, tpuv6e(), checkpoint=p,
              **{**GRID, "seed": 1})


def test_checkpoint_frame_roundtrip():
    rec = {"kind": "key", "k": "x", "stats": [[{"cycles": 1.25}]]}
    framed = SweepCheckpoint._frame(rec)
    assert SweepCheckpoint._parse_line(framed) == rec
    assert SweepCheckpoint._parse_line(framed[:-1]) is None     # no newline
    bad = bytearray(framed)
    bad[2] ^= 0x01
    assert SweepCheckpoint._parse_line(bytes(bad)) is None      # CRC catches
    assert zlib.crc32(b"") == 0         # sanity: zlib present on this runner


def test_fingerprint_digest_stable():
    d1 = fingerprint_digest({"a": (1, 2), "b": "x"})
    d2 = fingerprint_digest({"b": "x", "a": [1, 2]})
    assert d1 == d2                      # order/tuple-vs-list canonicalized
    assert d1 != fingerprint_digest({"a": (1, 3), "b": "x"})


# --------------------------------------------------------------------------
# Pareto search
# --------------------------------------------------------------------------

def test_nondominated_ranks():
    pts = [(1.0, 5.0), (2.0, 2.0), (5.0, 1.0),    # rank 0 (frontier)
           (2.0, 6.0), (3.0, 3.0),                # rank 1
           (4.0, 7.0)]                            # rank 2
    assert nondominated_ranks(pts) == [0, 0, 0, 1, 1, 2]


def test_pareto_front_keeps_ties(grid_result):
    front = pareto_front(grid_result.entries)
    pts = {(e.result.summary()["total_cycles"], e.result.summary()["energy_pj"])
           for e in front}
    # Every entry with a frontier value is ON the front (ties included).
    for e in grid_result.entries:
        s = e.result.summary()
        if (s["total_cycles"], s["energy_pj"]) in pts:
            assert e in front


def test_search_recovers_exact_front_within_budget(grid_result, small_wl):
    """Acceptance criterion on the 24-config reference grid shape: the
    driver's front equals the exhaustive front exactly (same configs, same
    bits) within <=50% of the exhaustive full-fidelity evaluations."""
    assert grid_result.num_configs == 24
    res = search(small_wl, tpuv6e(), policies=POLICIES,
                 capacities=CAPACITIES, ways=WAYS, zipf_s=0.9, seed=0)
    exhaustive = pareto_front(grid_result.entries)
    assert res.front_labels() == sorted(e.config.label for e in exhaustive)
    by_cfg = {e.config: e for e in grid_result.entries}
    for e in res.pareto:
        assert not e.result.diff(by_cfg[e.config].result), e.config.label
    assert res.full_evals <= 0.5 * grid_result.distinct_memo_keys, (
        res.full_evals, grid_result.distinct_memo_keys)
    # Survivors' full-fidelity entries are the exhaustive entries, bitwise.
    for e in res.population:
        assert not e.result.diff(by_cfg[e.config].result), e.config.label


def test_search_checkpointed_rungs_resume(small_wl, tmp_path):
    d = str(tmp_path / "rungs")
    res1 = search(small_wl, tpuv6e(), policies=POLICIES,
                  capacities=CAPACITIES, ways=WAYS, zipf_s=0.9, seed=0,
                  checkpoint_dir=d)
    assert os.path.isdir(d) and os.listdir(d)
    res2 = search(small_wl, tpuv6e(), policies=POLICIES,
                  capacities=CAPACITIES, ways=WAYS, zipf_s=0.9, seed=0,
                  checkpoint_dir=d)
    assert res1.front_labels() == res2.front_labels()
    for a, b in zip(res1.population, res2.population):
        assert a.config == b.config and not a.result.diff(b.result)


# --------------------------------------------------------------------------
# Result metadata
# --------------------------------------------------------------------------

def test_result_metadata_in_json(grid_result):
    payload = json.loads(grid_result.to_json())
    assert payload["device_count"] == 1
    assert payload["sharded"] is False
    assert payload["distinct_memo_keys"] == grid_result.distinct_memo_keys
    assert payload["resumed_keys"] == 0
